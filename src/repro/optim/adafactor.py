"""Adafactor (Shazeer & Stern, 2018): factored second moments.

For a [.., R, C] parameter the second moment is stored as row/col means
([.., R] + [.., C]) instead of [.., R, C] — O(R+C) optimizer memory.  This
is what makes 400B+-parameter MoE training fit a 16 GiB/chip pod at all:
deepseek-v3-671b's AdamW state alone (8 TB in f32) exceeds a 256-chip v5e
pod's 4 TB of HBM; Adafactor + bf16 masters fits with room for activations
(see EXPERIMENTS.md §Dry-run).

No first moment (beta1=0 variant), RMS-scaled relative step size, update
clipping — the configuration T5/PaLM trained with.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.base import ParamSpec, ps, tree_map_specs


@dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-2              # relative step size
    decay_pow: float = 0.8        # beta2_t = 1 - t^-decay_pow
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_factored: int = 128   # factor only tensors with both dims >= this


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128


def state_specs(param_specs, ocfg: AdafactorConfig) -> dict:
    def second_moment(_path, s: ParamSpec):
        if _factored(s.shape):
            return {
                "vr": ps(s.shape[:-1], s.axes[:-1], init="zeros", dtype=jnp.float32),
                "vc": ps(s.shape[:-2] + s.shape[-1:], s.axes[:-2] + s.axes[-1:],
                         init="zeros", dtype=jnp.float32),
            }
        return {"v": ps(s.shape, s.axes, init="zeros", dtype=jnp.float32)}

    return {
        "v": tree_map_specs(second_moment, param_specs),
        "step": ps((), (), init="zeros", dtype=jnp.int32),
    }


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x.astype(jnp.float32))))


# leaves bigger than this run their update under lax.map over the leading
# (layer-stack) dim: the f32 temporaries of a fused update over a stacked
# 400B-expert tensor are ~2x param size EACH and XLA keeps several alive
# (measured: ~20 GiB of f32[61,16,448,2048] buffers on deepseek-v3)
_CHUNK_ELEMS = 32 * 2**20


def _chunked(fn, p, g, v):
    if p.ndim >= 3 and p.size > _CHUNK_ELEMS and p.shape[0] > 1:
        def body(a):
            # the barrier pins the slice->f32 converts INSIDE the loop;
            # without it XLA:CPU hoists them and carries an f32 copy of
            # the whole stacked tensor (+2x param memory)
            return fn(*jax.lax.optimization_barrier(a))
        return jax.lax.map(body, (p, g, v))
    return fn(p, g, v)


def apply_updates(params, grads, opt_state, ocfg: AdafactorConfig):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-ocfg.decay_pow)
    lr = ocfg.lr * jnp.minimum(1.0, 10.0 / jnp.sqrt(t))  # brief warmup

    is_state = lambda n: isinstance(n, dict) and (set(n) <= {"v", "vr", "vc"})

    def upd(p, g, v):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + ocfg.eps1
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
            row_mean = vr.mean(-1, keepdims=True)
            precond = (vr / jnp.maximum(row_mean, ocfg.eps1))[..., None] * vc[..., None, :]
            new_v = {"vr": vr, "vc": vc}
        else:
            precond = beta2 * v["v"] + (1 - beta2) * g2
            new_v = {"v": precond}
        u = g32 * jax.lax.rsqrt(precond + ocfg.eps1)
        u = u / jnp.maximum(1.0, _rms(u) / ocfg.clip_threshold)
        scale = lr * jnp.maximum(_rms(p), ocfg.eps2)
        new_p = p.astype(jnp.float32) - scale * u
        if ocfg.weight_decay and p.ndim >= 2:
            new_p = new_p - lr * ocfg.weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), new_v

    out = jax.tree.map(lambda p, g, v: _chunked(upd, p, g, v),
                       params, grads, opt_state["v"],
                       is_leaf=lambda n: is_state(n) and not isinstance(n, jnp.ndarray))
    # out mirrors params' structure with (new_p, new_v) tuples at leaves
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"v": new_v, "step": step}, lr
