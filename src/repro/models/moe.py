"""Mixture-of-Experts layers (DeepSeek-V3 with MLA, Snowflake Arctic).

Dispatch is batch-blocked and capacity-bounded: tokens of each batch row are
scattered into a [B, E, C, D] buffer sharded batch->data and experts->model,
so expert compute is fully local (EP) and the only collective is the combine
all-reduce over the model axis — the same bytes a TP MLP would move.  This
is the Fix story at the kernel level: the platform sees exactly which
experts' weights each token needs (the router's selection thunks) and moves
activations, never weights.

DeepSeek-V3's MLA keeps a compressed KV (kv_lora + rope dims = 576 floats
per token); decode uses the absorbed-matmul form so the cache stays
compressed end-to-end.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .base import (
    apply_remat,
    scan_layers,
    ModelConfig,
    attend,
    causal_mask,
    embed_tokens,
    ps,
    rmsnorm,
    rope,
    swiglu,
    unembed,
)

# ---------------------------------------------------------------- routing
def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(np.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # pad to 8 for TPU-friendly tiling


def _route_and_ffn(x_tok, router_w, w_gate, w_up, w_down, cfg: ModelConfig, C: int):
    """Dispatch T tokens to E local-resident experts and combine.

    Pure-local math (no sharded scatters): x_tok [T, D], router_w [D, E],
    expert weights [E, D, F] / [E, F, D].  Used directly on one device, or
    per-shard inside shard_map with E = local experts.
    """
    T, D = x_tok.shape
    E_tot, K = cfg.n_experts, cfg.top_k
    E_loc = w_gate.shape[0]

    logits = jnp.einsum("td,de->te", x_tok, router_w.astype(x_tok.dtype))
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_k, idx_k = jax.lax.top_k(gates, K)                        # [T, K]
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    flat_e = idx_k.reshape(T * K)
    flat_g = gate_k.reshape(T * K)
    tok_id = jnp.repeat(jnp.arange(T), K)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_g = flat_g[order]
    sorted_t = tok_id[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E_tot))
    pos = jnp.arange(T * K) - seg_start[sorted_e]

    # under EP, this shard owns experts [e0, e0 + E_loc); others drop
    if E_loc != E_tot:
        e0 = jax.lax.axis_index("model") * E_loc
        local_e = sorted_e - e0
    else:
        local_e = sorted_e

    buf = jnp.zeros((E_loc, C, D), x_tok.dtype)
    buf = buf.at[local_e, pos].set(x_tok[sorted_t], mode="drop")
    tok_buf = jnp.full((E_loc, C), T, jnp.int32)      # sentinel T => dropped
    tok_buf = tok_buf.at[local_e, pos].set(sorted_t, mode="drop")
    gate_buf = jnp.zeros((E_loc, C), jnp.float32)
    gate_buf = gate_buf.at[local_e, pos].set(sorted_g, mode="drop")

    h_g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(x_tok.dtype))
    h_u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(x_tok.dtype))
    h = jax.nn.silu(h_g) * h_u
    buf_out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x_tok.dtype))
    buf_out = buf_out * gate_buf[..., None].astype(x_tok.dtype)

    y = jnp.zeros((T + 1, D), x_tok.dtype)
    y = y.at[tok_buf].add(buf_out, mode="drop")
    return y[:T]


def _batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def moe_ffn(x, mp, cfg: ModelConfig, sh):
    """x: [B, S, D] -> [B, S, D].  Router -> top-k -> capacity dispatch ->
    per-expert SwiGLU -> weighted combine (+ shared experts / dense residual).

    With a mesh, the routed path runs under shard_map: tokens stay on their
    (pod, data) shard, each model shard dispatches to its resident experts
    with *local* scatters (SPMD scatter partitioning otherwise falls back to
    full replication — measured 56 GiB/device on arctic prefill), expert
    weights are explicitly FSDP-gathered over "data", and the combine is one
    psum over "model" — the Fix thesis in kernel form: move activations,
    never expert weights.
    """
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    E = cfg.n_experts
    mesh = sh.mesh

    use_shard_map = False
    if mesh is not None:
        axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_model = axes.get("model", 1)
        n_batch = 1
        for a in _batch_axes(mesh):
            n_batch *= axes[a]
        use_shard_map = (B % n_batch == 0) and (E % n_model == 0)

    if use_shard_map and S == 1:
        # ---- decode path: ship activations, not weights -----------------
        # One token per row: gathering 1.4 GB/layer of FSDP'd expert weights
        # to process 8 local tokens is the paper's pathology in reverse.
        # Instead: all-gather the (tiny) token batch over "data", keep every
        # weight D-shard resident, psum the partial matmuls, and all-to-all
        # the D-sharded outputs back to token homes.  Measured on
        # deepseek-v3 decode_32k: collective bytes/layer 1.4 GB -> ~4 MB.
        batch_axes = _batch_axes(mesh)
        n_data = axes.get("data", 1)
        T_loc = B // n_batch
        T_all = T_loc * n_data
        C = _capacity(cfg, T_all)

        def per_shard_decode(x_loc, router_w, w_gate, w_up, w_down):
            D_loc = router_w.shape[0]
            t_loc = x_loc.shape[0]
            x2 = x_loc.reshape(t_loc, D)
            if "data" in mesh.axis_names and n_data > 1:
                x_all = jax.lax.all_gather(x2, "data", axis=0, tiled=True)
            else:
                x_all = x2
            d0 = (jax.lax.axis_index("data") * D_loc
                  if "data" in mesh.axis_names else 0)
            x_slice = jax.lax.dynamic_slice_in_dim(x_all, d0, D_loc, axis=1)
            logits = jnp.einsum("td,de->te", x_slice,
                                router_w.astype(x_slice.dtype))
            if "data" in mesh.axis_names and n_data > 1:
                logits = jax.lax.psum(logits, "data")
            gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
            gate_k, idx_k = jax.lax.top_k(gates, cfg.top_k)
            gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)
            K = cfg.top_k
            flat_e = idx_k.reshape(T_all * K)
            flat_g = gate_k.reshape(T_all * K)
            tok_id = jnp.repeat(jnp.arange(T_all), K)
            order = jnp.argsort(flat_e, stable=True)
            sorted_e, sorted_g, sorted_t = flat_e[order], flat_g[order], tok_id[order]
            seg = jnp.searchsorted(sorted_e, jnp.arange(cfg.n_experts))
            pos = jnp.arange(T_all * K) - seg[sorted_e]
            E_loc = w_gate.shape[0]
            local_e = sorted_e - jax.lax.axis_index("model") * E_loc

            buf = jnp.zeros((E_loc, C, D_loc), x_all.dtype)
            buf = buf.at[local_e, pos].set(x_slice[sorted_t], mode="drop")
            tok_buf = jnp.full((E_loc, C), T_all, jnp.int32)
            tok_buf = tok_buf.at[local_e, pos].set(sorted_t, mode="drop")
            gate_buf = jnp.zeros((E_loc, C), jnp.float32)
            gate_buf = gate_buf.at[local_e, pos].set(sorted_g, mode="drop")

            h_g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
            h_u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
            if "data" in mesh.axis_names and n_data > 1:  # D-partial matmuls
                h_g = jax.lax.psum(h_g, "data")
                h_u = jax.lax.psum(h_u, "data")
            h = jax.nn.silu(h_g) * h_u
            out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(h.dtype))
            out = out * gate_buf[..., None].astype(out.dtype)
            y = jnp.zeros((T_all + 1, D_loc), x_all.dtype)
            y = y.at[tok_buf].add(out, mode="drop")
            y = jax.lax.psum(y[:T_all], "model")
            if "data" in mesh.axis_names and n_data > 1:
                # [T_all, D_loc] -> [T_loc, D]: transpose token/dim sharding
                y = y.reshape(n_data, T_loc, D_loc)
                y = jax.lax.all_to_all(y, "data", split_axis=0, concat_axis=2,
                                       tiled=False)
                y = y.reshape(T_loc, D)
            return y.reshape(t_loc, 1, D)

        y = jax.shard_map(
            per_shard_decode, mesh=mesh,
            in_specs=(P(batch_axes, None, None), P("data", None),
                      P("model", "data", None), P("model", "data", None),
                      P("model", None, "data")),
            out_specs=P(batch_axes, None, None),
            check_vma=False,
        )(x, mp["router"], mp["w_gate"], mp["w_up"], mp["w_down"])
    elif use_shard_map:
        batch_axes = _batch_axes(mesh)
        T_loc = (B // n_batch) * S
        C = _capacity(cfg, T_loc)

        def per_shard(x_loc, router_w, w_gate, w_up, w_down):
            # explicit FSDP: gather the D-sharded expert weights over "data"
            if "data" in mesh.axis_names:
                router_w = jax.lax.all_gather(router_w, "data", axis=0, tiled=True)
                w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
                w_up = jax.lax.all_gather(w_up, "data", axis=1, tiled=True)
                w_down = jax.lax.all_gather(w_down, "data", axis=2, tiled=True)
            b_loc = x_loc.shape[0]
            y = _route_and_ffn(x_loc.reshape(b_loc * S, D), router_w,
                               w_gate, w_up, w_down, cfg, C)
            y = jax.lax.psum(y, "model")
            return y.reshape(b_loc, S, D)

        y = jax.shard_map(
            per_shard, mesh=mesh,
            in_specs=(P(batch_axes, None, None), P("data", None),
                      P("model", "data", None), P("model", "data", None),
                      P("model", None, "data")),
            out_specs=P(batch_axes, None, None),
            check_vma=False,
        )(x, mp["router"], mp["w_gate"], mp["w_up"], mp["w_down"])
    else:
        C = _capacity(cfg, B * S)
        y = _route_and_ffn(x.reshape(B * S, D), mp["router"], mp["w_gate"],
                           mp["w_up"], mp["w_down"], cfg, C).reshape(B, S, D)
    y = sh(y, "batch", "seq", "embed")

    if cfg.n_shared_experts:
        y = y + swiglu(x, mp["shared_gate"].astype(x.dtype),
                       mp["shared_up"].astype(x.dtype),
                       mp["shared_down"].astype(x.dtype), sh)
    if cfg.dense_residual:
        y = y + swiglu(x, mp["res_gate"].astype(x.dtype),
                       mp["res_up"].astype(x.dtype),
                       mp["res_down"].astype(x.dtype), sh)
    return y


def moe_layer_specs(cfg: ModelConfig, n_layers: int) -> dict:
    L, D, E = n_layers, cfg.d_model, cfg.n_experts
    Fe = cfg.d_ff_expert or cfg.d_ff
    specs = {
        "router": ps((L, D, E), ("p_layers", "p_embed", "p_none")),
        "w_gate": ps((L, E, D, Fe), ("p_layers", "p_experts", "p_embed", "p_none")),
        "w_up": ps((L, E, D, Fe), ("p_layers", "p_experts", "p_embed", "p_none")),
        "w_down": ps((L, E, Fe, D), ("p_layers", "p_experts", "p_none", "p_embed")),
    }
    if cfg.n_shared_experts:
        Fs = Fe * cfg.n_shared_experts
        specs.update(
            shared_gate=ps((L, D, Fs), ("p_layers", "p_embed", "p_mlp")),
            shared_up=ps((L, D, Fs), ("p_layers", "p_embed", "p_mlp")),
            shared_down=ps((L, Fs, D), ("p_layers", "p_mlp", "p_embed")),
        )
    if cfg.dense_residual:
        F = cfg.d_ff
        specs.update(
            res_gate=ps((L, D, F), ("p_layers", "p_embed", "p_mlp")),
            res_up=ps((L, D, F), ("p_layers", "p_embed", "p_mlp")),
            res_down=ps((L, F, D), ("p_layers", "p_mlp", "p_embed")),
        )
    return specs


# -------------------------------------------------------------------- MLA
def mla_layer_specs(cfg: ModelConfig, n_layers: int) -> dict:
    L, D, H = n_layers, cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": ps((L, D, qr), ("p_layers", "p_embed", "p_lora")),
        "q_norm": ps((L, qr), ("p_layers", "p_none"), init="ones"),
        "wq_b": ps((L, qr, H, dn + dr), ("p_layers", "p_lora", "p_heads", "p_none")),
        "wkv_a": ps((L, D, kr + dr), ("p_layers", "p_embed", "p_lora")),
        "kv_norm": ps((L, kr), ("p_layers", "p_none"), init="ones"),
        "wk_b": ps((L, kr, H, dn), ("p_layers", "p_lora", "p_heads", "p_none")),
        "wv_b": ps((L, kr, H, dv), ("p_layers", "p_lora", "p_heads", "p_none")),
        "wo": ps((L, H, dv, D), ("p_layers", "p_heads", "p_none", "p_embed")),
    }


def mla_attn(x, lp, cfg: ModelConfig, sh, positions, kv_cache=None):
    """Multi-head Latent Attention.

    Train/prefill: materialize per-head K/V from the compressed latent.
    Decode: absorbed-matmul form over the compressed cache
    [B, T, kv_lora + rope_head_dim] — 576 floats/token for V3.
    Returns (attn out [B,S,D], (c_kv, k_rope) cache pair).
    """
    B, S, D = x.shape
    H = cfg.n_heads
    kr, dn, dr, dv = cfg.kv_lora_rank, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    dt = x.dtype

    q_lat = rmsnorm(jnp.einsum("bsd,dr->bsr", x, lp["wq_a"].astype(dt)),
                    lp["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, lp["wq_b"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, lp["wkv_a"].astype(dt))
    c_kv = rmsnorm(kv_a[..., :kr], lp["kv_norm"], cfg.norm_eps)   # [B,S,kr]
    k_rope_new = rope(kv_a[..., kr:][:, :, None, :], positions,
                      cfg.rope_theta)[:, :, 0, :]                   # [B,S,dr] shared
    scale = 1.0 / np.sqrt(dn + dr)

    if kv_cache is None:
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, lp["wk_b"].astype(dt))
        v = jnp.einsum("bsr,rhk->bshk", c_kv, lp["wv_b"].astype(dt))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope_new[:, :, None, :],
                                                      (B, S, H, dr))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        qq = sh(qq, "batch", "seq", "heads", None)
        k = sh(k, "batch", "seq", "heads", None)
        v = sh(v, "batch", "seq", "heads", None)
        o = attend(qq, k, v, None, sh, pattern="causal")  # scaled 1/sqrt(dn+dr)
        cache = (c_kv, k_rope_new)
    else:
        c_all, kr_all, pos = kv_cache
        c_all = jax.lax.dynamic_update_slice(c_all, c_kv.astype(c_all.dtype), (0, pos, 0))
        kr_all = jax.lax.dynamic_update_slice(kr_all, k_rope_new.astype(kr_all.dtype),
                                              (0, pos, 0))
        c_all = sh(c_all, "batch", "kv_seq", None)
        kr_all = sh(kr_all, "batch", "kv_seq", None)
        mask = (jnp.arange(c_all.shape[1]) <= pos)[None, None, None, :]
        # absorb: q_eff[b,s,h,r] = q_nope . wk_b
        q_eff = jnp.einsum("bshn,rhn->bshr", q_nope, lp["wk_b"].astype(dt))
        s_nope = jnp.einsum("bshr,btr->bhst", q_eff, c_all.astype(dt))
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, kr_all.astype(dt))
        scores = (s_nope + s_rope).astype(jnp.float32) * scale
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        ctx = jnp.einsum("bhst,btr->bshr", probs, c_all.astype(dt))   # compressed ctx
        o = jnp.einsum("bshr,rhv->bshv", ctx, lp["wv_b"].astype(dt))
        cache = (c_all, kr_all)

    o = sh(o, "batch", "seq", "heads", None)
    out = jnp.einsum("bshv,hvd->bsd", o, lp["wo"].astype(dt))
    return sh(out, "batch", "seq", "embed"), cache


# ------------------------------------------------------------ full model
def moe_specs(cfg: ModelConfig) -> dict:
    from .transformer import dense_layer_specs

    Vp, D, L = cfg.vocab_padded, cfg.d_model, cfg.n_layers
    if cfg.mla:
        attn = mla_layer_specs(cfg, L)
    else:
        attn = {k: v for k, v in dense_layer_specs(cfg, L).items()
                if not k.startswith(("w_gate", "w_up", "w_down", "mlp_norm"))}
    layers = dict(attn)
    layers["moe_norm"] = ps((L, D), ("p_layers", "p_none"), init="ones")
    if "attn_norm" not in layers:
        layers["attn_norm"] = ps((L, D), ("p_layers", "p_none"), init="ones")
    layers.update(moe_layer_specs(cfg, L))
    specs = {
        "embed": ps((Vp, D), ("p_vocab", "p_embed"), init="embed", scale=0.02),
        "layers": layers,
        "final_norm": ps((D,), ("p_none",), init="ones"),
        "unembed": ps((D, Vp), ("p_embed", "p_vocab")),
    }
    if cfg.mtp:  # DeepSeek-V3 multi-token prediction head (off in dry-runs)
        specs["mtp_norm"] = ps((D,), ("p_none",), init="ones")
        specs["mtp_proj"] = ps((2 * D, D), ("p_none", "p_embed"))
    return specs


def moe_block(x, lp, cfg: ModelConfig, sh, positions, kv_cache=None):
    if cfg.mla:
        a, kv = mla_attn(rmsnorm(x, lp["attn_norm"], cfg.norm_eps), lp, cfg, sh,
                         positions, kv_cache)
        x = x + a
    else:
        from .transformer import attn_block
        x, kv = attn_block(x, lp, cfg, sh, positions, kv_cache)
    h = rmsnorm(x, lp["moe_norm"], cfg.norm_eps)
    x = x + moe_ffn(h, lp, cfg, sh)
    return x, kv


def moe_forward(params, batch, cfg: ModelConfig, sh, remat_policy=None,
                remat_group: int = 1):
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), batch["tokens"], sh)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        x, _ = moe_block(x, lp, cfg, sh, positions)
        return x, None

    x, _ = scan_layers(body, x, params["layers"], remat_policy, remat_group)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"].astype(x.dtype), sh)
    if cfg.mtp and "mtp_proj" in params:
        h2 = jnp.concatenate([x[:, :-1], x[:, 1:]], axis=-1)
        h2 = jnp.einsum("bse,ed->bsd", h2, params["mtp_proj"].astype(x.dtype))
        h2 = rmsnorm(h2, params["mtp_norm"], cfg.norm_eps)
        mtp_logits = unembed(h2, params["unembed"].astype(x.dtype), sh)
        return logits, mtp_logits
    return logits


def moe_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    L = cfg.n_layers
    if cfg.mla:
        return {
            "c_kv": ps((L, batch, max_seq, cfg.kv_lora_rank),
                       ("p_layers", "batch", "kv_seq", "p_none"), init="zeros",
                       dtype=cfg.compute_dtype),
            "k_rope": ps((L, batch, max_seq, cfg.rope_head_dim),
                         ("p_layers", "batch", "kv_seq", "p_none"), init="zeros",
                         dtype=cfg.compute_dtype),
            "pos": ps((), (), init="zeros", dtype=jnp.int32),
        }
    from .transformer import dense_cache_specs
    return dense_cache_specs(cfg, batch, max_seq)


def moe_decode_step(params, cache, tokens, cfg: ModelConfig, sh):
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), tokens, sh)
    pos = cache["pos"]
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)

    if cfg.mla:
        def body(x, layer):
            lp, c_all, kr_all = layer
            x, (c_new, kr_new) = moe_block(x, lp, cfg, sh, positions,
                                           kv_cache=(c_all, kr_all, pos))
            return x, (c_new, kr_new)

        x, (c_stack, kr_stack) = jax.lax.scan(
            body, x, (params["layers"], cache["c_kv"], cache["k_rope"]))
        new_cache = {"c_kv": c_stack, "k_rope": kr_stack, "pos": pos + 1}
    else:
        def body(x, layer):
            lp, k_all, v_all = layer
            x, (k_new, v_new) = moe_block(x, lp, cfg, sh, positions,
                                          kv_cache=(k_all, v_all, pos))
            return x, (k_new, v_new)

        x, (k_stack, v_stack) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": k_stack, "v": v_stack, "pos": pos + 1}
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"].astype(x.dtype), sh)
    return logits, new_cache


def moe_prefill(params, batch, cfg: ModelConfig, sh):
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), batch["tokens"], sh)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        x, kv = moe_block(x, lp, cfg, sh, positions)
        return x, kv

    x, caches = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x[:, -1:], params["unembed"].astype(x.dtype), sh)
    if cfg.mla:
        cache = {"c_kv": sh(caches[0], None, "batch", "kv_seq", None),
                 "k_rope": sh(caches[1], None, "batch", "kv_seq", None),
                 "pos": jnp.asarray(S, jnp.int32)}
    else:
        cache = {"k": sh(caches[0], None, "batch", "kv_seq", "kv_heads", None),
                 "v": sh(caches[1], None, "batch", "kv_seq", "kv_heads", None),
                 "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache
