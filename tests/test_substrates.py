"""Substrate tests: data pipeline, checkpointing, serving, optimizers,
gradient compression, elastic restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import dedup_stats, load_step, save_step
from repro.core import Evaluator, Repository
from repro.data import TokenPipeline, corpus_handle
from repro.models import ModelConfig, init_params, ops_for
from repro.optim import adafactor, adamw
from repro.optim.compress import ef_int8_allreduce
from repro.serving import PrefixCache, Request, ServeEngine, prompt_key

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=256,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)


# ---------------------------------------------------------------- data
class TestDataPipeline:
    def test_deterministic_batches(self):
        repo = Repository()
        ev = Evaluator(repo)
        corpus = corpus_handle(repo, 1 << 16)
        pipe = TokenPipeline(repo, corpus, seq_len=32, batch=4, vocab=256)
        b1 = pipe.batch_for_step(ev, 3)
        b2 = pipe.batch_for_step(ev, 3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])

    def test_shard_is_recomputable(self):
        """The shard thunk re-derives identical bytes in a fresh repo."""
        r1, r2 = Repository(), Repository()
        c1 = corpus_handle(r1, 1 << 14)
        c2 = corpus_handle(r2, 1 << 14)
        assert c1 == c2  # same seed => same corpus hash
        p1 = TokenPipeline(r1, c1, 16, 2)
        p2 = TokenPipeline(r2, c2, 16, 2)
        o1 = Evaluator(r1).evaluate(p1.shard_thunk(5).strict())
        o2 = Evaluator(r2).evaluate(p2.shard_thunk(5).strict())
        assert o1.content_key() == o2.content_key()


# ------------------------------------------------------------ checkpoint
class TestCheckpoint:
    def test_save_load_roundtrip_and_dedup(self):
        repo = Repository()
        ops = ops_for(CFG)
        params = init_params(ops.specs(CFG), CFG)
        state = {"params": params, "opt": {"step": jnp.zeros((), jnp.int32)}}
        r1 = save_step(repo, state, 1)
        # mutate one leaf only
        state2 = jax.tree.map(lambda x: x, state)
        state2["params"]["final_norm"] = state["params"]["final_norm"] + 1
        r2 = save_step(repo, state2, 2)
        meta, back = load_step(repo, r2)
        assert meta["step"] == 2
        np.testing.assert_allclose(back["params"]["final_norm"],
                                   np.asarray(state2["params"]["final_norm"]))
        stats = dedup_stats(repo, [r1, r2])
        assert stats["unique_leaves"] < stats["leaf_refs"]  # dedup happened

    def test_elastic_restore_reshards(self):
        """Restore onto a different mesh: arrays go to new shardings."""
        import os

        repo = Repository()
        ops = ops_for(CFG)
        params = init_params(ops.specs(CFG), CFG)
        root = save_step(repo, {"params": params}, 7)
        meta, back = load_step(repo, root)  # host "mesh"
        assert meta["step"] == 7
        for path in (("params", "embed"), ("params", "final_norm")):
            a = back
            b = {"params": params}
            for k in path:
                a, b = a[k], b[k]
            np.testing.assert_array_equal(a, np.asarray(b))


# --------------------------------------------------------------- serving
class TestServing:
    def test_engine_continuous_batching(self):
        # toy "model" in the batched contracts: state = last token seen;
        # next token = (last + 1) % 7 (eos -1 never fires)
        def prefill(tokens, state=None):
            return int(tokens[-1])

        def decode(states, tokens):
            logits = np.zeros((len(states), 1, 8), np.float32)
            out = []
            for b, last in enumerate(tokens[:, 0]):
                nxt = (int(last) + 1) % 7
                logits[b, 0, nxt] = 1.0
                out.append(nxt)
            return logits, out

        eng = ServeEngine(prefill, decode, batch=2, eos=-1, block=16)
        reqs = [Request(rid=i, prompt=np.asarray([i, i + 1], np.int32), max_new=5)
                for i in range(5)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done and len(r.out_tokens) == 5 for r in reqs)
        # batch never exceeded 2 live rows: steps >= ceil(5*5/2)
        assert eng.steps >= 13

    def test_prefix_cache_block_identity(self):
        a = np.arange(64, dtype=np.int32)
        b = np.concatenate([np.arange(32, dtype=np.int32),
                            np.arange(100, 132, dtype=np.int32)])
        ka, kb = prompt_key(a, block=16), prompt_key(b, block=16)
        assert ka[0] == kb[0] and ka[1] == kb[1]  # shared 32-token prefix
        assert ka[2] != kb[2]
        cache = PrefixCache(4)
        # states are per-boundary: a 4-block insert without its ancestors
        # would dangle (the seed engine cached one whole-prompt state here,
        # which a shorter lookup then wrongly resumed from) — refused now
        assert not cache.insert(ka, "state-a3")
        for j in range(4):
            assert cache.insert(ka[: j + 1], f"state-a{j}")
        n, st = cache.lookup(kb)
        assert n == 2 and st == "state-a1"  # the state of exactly 2 blocks


# -------------------------------------------------------------- optimizers
class TestOptimizers:
    def _quad_problem(self):
        params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
        grads_fn = lambda p: {"w": 2 * p["w"]}
        return params, grads_fn

    def test_adamw_converges(self):
        params, grads_fn = self._quad_problem()
        ocfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, weight_decay=0.0)
        specs = {"w": __import__("repro.models.base", fromlist=["ps"]).ps(
            (3,), ("p_none",))}
        state = {"mu": {"w": jnp.zeros(3)}, "nu": {"w": jnp.zeros(3)},
                 "step": jnp.zeros((), jnp.int32)}
        for _ in range(200):
            params, state, _ = adamw.apply_updates(params, grads_fn(params),
                                                   state, ocfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_adafactor_converges_and_is_factored(self):
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (128, 256)) * 3}
        specs = {"w": __import__("repro.models.base", fromlist=["ps"]).ps(
            (128, 256), ("p_none", "p_none"))}
        st_specs = adafactor.state_specs(specs, adafactor.AdafactorConfig())
        assert "vr" in st_specs["v"]["w"]  # factored: O(R+C) not O(RC)
        state = {"v": {"w": {"vr": jnp.zeros(128), "vc": jnp.zeros(256)}},
                 "step": jnp.zeros((), jnp.int32)}
        ocfg = adafactor.AdafactorConfig(lr=0.05)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adafactor.apply_updates(params, grads, state, ocfg)
        assert float(jnp.abs(params["w"]).mean()) < 0.05

    def test_ef_int8_compression_bounded_error(self):
        """Single-host simulation of the 2-pod EF-int8 all-reduce."""
        from repro.launch.mesh import axis_type_kwargs
        from repro.parallel import compat_shard_map

        mesh = jax.make_mesh((1,), ("pod",), **axis_type_kwargs(1))
        g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 0.01
        err = jnp.zeros_like(g)

        def run(g, err):
            return ef_int8_allreduce(g, err, "pod", 1)

        from jax.sharding import PartitionSpec as P

        out, new_err = jax.jit(compat_shard_map(run, mesh=mesh,
                                                in_specs=(P(), P()),
                                                out_specs=(P(), P())))(g, err)
        # quantization error bounded by scale/2, and error feedback captures it
        scale = float(jnp.abs(g).max()) / 127
        assert float(jnp.abs(out - g).max()) <= scale
        np.testing.assert_allclose(np.asarray(out + new_err),
                                   np.asarray(g), atol=1e-6)
